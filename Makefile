PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: tier1 tier1-shard test bench bench-smoke bench-trajectory \
        bench-trajectory-smoke bench-compare bench-compare-prev \
        chaos-smoke obs-smoke lint-locks

# Fast verification gate: everything except the `slow`-marked end-to-end
# tests (test_distributed.py spawns an 8-device subprocess mesh,
# test_system.py runs full ingest->analyze->update sweeps).
tier1:
	$(PY) -m pytest -x -q -m "not slow"

# Quick-iteration gate for the sharded service + storage engine work:
# just the shard and durability suites.
tier1-shard:
	$(PY) -m pytest -x -q -m "not slow" tests/test_shard.py tests/test_storage.py

# Full sweep — the canonical tier-1 command from ROADMAP.md.
test:
	$(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run

# Benchmark bit-rot gate: tiny-scale run of every registered suite;
# asserts exit 0 + the name,us_per_call,derived row schema (JSON report).
bench-smoke:
	BENCH_SMOKE=1 $(PY) -m benchmarks.smoke

# Persisted perf trajectory: run every suite at the pinned scale plus the
# amplification probe and write BENCH_PR$(PR).json at the repo root (the
# file each PR commits; see benchmarks/trajectory.py).
PR ?= 9
bench-trajectory:
	$(PY) -m benchmarks.trajectory --pr $(PR)

# Diff two trajectory files; non-zero exit on >threshold regression.
# Usage: make bench-compare BASE=BENCH_PR8.json CAND=BENCH_PR9.json
BASE ?= BENCH_PR$(PR).json
CAND ?= BENCH_PR$(PR).json
bench-compare:
	$(PY) tools/bench_compare.py $(BASE) $(CAND)

# CI drift gate vs the previous PR's committed trajectory: run a fresh
# smoke-scale trajectory and schema-compare it against the newest
# committed BENCH_PR<N>.json (row presence only — smoke timings are
# noise, so no numeric thresholds; see tools/bench_compare.py
# --schema-only).
PREV ?= $(shell ls BENCH_PR*.json 2>/dev/null | sort -V | tail -1)
bench-compare-prev:
	@test -n "$(PREV)" || { echo "no committed BENCH_PR*.json"; exit 1; }
	BENCH_SMOKE=1 $(PY) -m benchmarks.trajectory --pr 0 \
		--out /tmp/bench_prev_cand.json
	$(PY) tools/bench_compare.py --schema-only $(PREV) \
		/tmp/bench_prev_cand.json

# CI gate for the trajectory pipeline: tiny-scale run, schema validation,
# and a bench_compare round-trip (identical passes, inflated copy fails).
bench-trajectory-smoke:
	$(PY) tools/bench_trajectory_smoke.py

# Fault-injection gate: a fixed-seed batch of randomized fault schedules
# (failed fsyncs, torn WAL writes, read EIO, segment bit-flips) through the
# durability invariants — acked writes survive reopen, reads fail typed.
# Fixed seeds keep it deterministic and under ~30s.
chaos-smoke:
	$(PY) -m repro.storage.chaostest --schedules 12 --seed 0

# Metrics-pipeline gate: tiny-scale `graph_service --metrics` runs (single
# durable + sharded durable) with schema validation of the per-phase
# reports — every per-layer metric family must be present and well-formed.
obs-smoke:
	$(PY) tools/obs_smoke.py

# Lock-discipline gate: AST lint of core/store.py — no device work under
# the commit lock, no writer-lock acquisition on the snapshot read path
# (the two invariants the epoch-published StoreState design rests on).
lint-locks:
	$(PY) tools/lint_locks.py
