PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: tier1 test bench

# Fast verification gate: everything except the `slow`-marked end-to-end
# tests (test_distributed.py spawns an 8-device subprocess mesh,
# test_system.py runs full ingest->analyze->update sweeps).
tier1:
	$(PY) -m pytest -x -q -m "not slow"

# Full sweep — the canonical tier-1 command from ROADMAP.md.
test:
	$(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run
